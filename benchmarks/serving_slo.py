"""Serving SLO under a fault storm — the paper's downtime claim on live
traffic.

    PYTHONPATH=src python -m benchmarks.serving_slo --smoke

The training benchmarks measure lost steps per fault; a serving system
measures what a CLIENT sees.  This benchmark drives the continuous-
batching engine (``repro.serving``) with an open-loop arrival process at a
target QPS, runs the same schedule twice — fault-free baseline vs a bit-
flip storm into the canary's protected window — and reports the SLO view:

* throughput (tokens/s, achieved QPS) under the storm,
* p50/p99 **added** end-to-end latency per request (storm minus baseline,
  same request, same arrival time — the storm's queueing + replay cost),
* dropped requests (hard-asserted 0 for healthy, i.e. never-injured,
  requests),
* per-fault recovery wall time (slot eviction -> victim re-admitted).

Two correctness properties are HARD-ASSERTED (overhead.py-style), not
just reported:

* **slot isolation**: every healthy request's token sequence is
  bit-identical to its fault-free run — faults in other slots added
  latency, never changed bytes.  (Injured requests are also bit-identical
  here: every storm flip is detected, and prefix replay + deterministic
  re-decode regenerates the same tokens.  The assert keys on healthy
  requests because that is the isolation claim; injured bit-exactness is
  reported.)
* **steady-state hot path**: at full slots with no admissions, an engine
  step is EXACTLY 1 logical launch + 1 scalar fault sync with 0 digest
  retraces, and admission/eviction at steady state causes 0 retraces —
  including paged block-pool alloc/free churn across DIFFERENT prompt
  lengths (slice writes through pre-compiled executables, never a
  recompile).

With ``--prefill-chunk`` > 0 a third, fault-free pair of runs compares
chunked against monolithic prefill on the same heterogeneous schedule
(``--long-prompt``/``--long-every`` mix a long-prompt tail into the
arrivals): chunked prefill must not change a single output token
(asserted) and must keep short requests' e2e p99 within a loose bound of
the monolithic run's (a long prompt's prefill no longer stalls the
decode batch wholesale).

``--out`` writes machine-readable ``BENCH_serving.json`` (QPS, tokens/s,
p99 added latency, dropped counts) so the serving perf trajectory is
tracked across PRs; ``benchmarks/run.py`` registers this as its serving
section.
"""

from __future__ import annotations

import argparse
import json
import os
import random
import time
from typing import Dict, List, Optional

import numpy as np

from repro.configs import get_config
from repro.kernels import digest as kdigest
from repro.serving import Request, ServingEngine

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), os.pardir,
                           "BENCH_serving.json")


def _pct(xs: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs, np.float64), q)) if xs else 0.0


def _make_requests(cfg, n: int, prompt_len: int, gen_tokens: int,
                   qps: float, nprng, long_prompt: int = 0,
                   long_every: int = 0) -> List[Request]:
    """Open-loop arrivals: exponential inter-arrival times at ``qps``
    (Poisson process), seeded — both runs see the SAME schedule.
    ``long_prompt``/``long_every`` mix in a heterogeneous tail: every
    ``long_every``-th request carries a ``long_prompt``-token prompt (the
    paged pool's block-budget admission and the chunked-prefill fairness
    section both need the mix)."""
    arrivals = np.cumsum(nprng.exponential(1.0 / qps, size=n))
    vocab = cfg.model.vocab_size
    reqs = []
    for i in range(n):
        plen = (long_prompt if long_every and long_prompt
                and i % long_every == long_every - 1 else prompt_len)
        reqs.append(Request(
            rid=i,
            prompt=nprng.integers(0, vocab, size=plen).astype(np.int32),
            max_new_tokens=gen_tokens,
            arrival_s=float(arrivals[i])))
    return reqs


def steady_state(cfg, *, n_slots: int = 4, canary_slices: int = 4,
                 steps: int = 16, seed: int = 0, paged=None,
                 block_size: int = 8, prefill_chunk: int = 0) -> Dict:
    """Hard-assert the engine's hot-path contract (the serving analogue of
    overhead.fused_steady_state):

    * full slots, no admissions: 1 logical launch + 1 scalar sync + 0
      retraces per engine step;
    * an eviction + admission at steady state retraces NOTHING — slot
      turnover is slice writes through pre-compiled executables, and
      under paging the re-admission uses a DIFFERENT prompt length
      (different block count), so block-pool alloc/free churn is part of
      the asserted contract.
    """
    nprng = np.random.default_rng(seed)
    eng = ServingEngine(cfg, n_slots=n_slots, max_len=64,
                        canary_slices=canary_slices, donate=True, seed=seed,
                        paged=paged, block_size=block_size,
                        prefill_chunk=prefill_chunk)
    warm_s = eng.warm()
    vocab = cfg.model.vocab_size
    mk = lambda rid, plen=8: Request(
        rid=rid, prompt=nprng.integers(0, vocab, size=plen).astype(np.int32),
        max_new_tokens=eng.max_len - plen - 1)   # outlives the window
    for u in range(n_slots):
        eng.admit(mk(u), u)
    for _ in range(max(1, canary_slices)):   # settle one full rotation
        _, _, rep = eng.engine_step()
        assert rep is None
    kdigest.STATS.reset()
    for _ in range(steps):
        _, _, rep = eng.engine_step()
        assert rep is None, "phantom fault in the steady-state window"
    launches, syncs, traces = kdigest.STATS.snapshot()
    assert launches == steps and syncs == steps and traces == 0, (
        "serving steady state must be 1 logical launch + 1 scalar fault "
        f"sync + 0 retraces per engine step, got {launches}/{syncs}/"
        f"{traces} over {steps} steps")

    # slot turnover at steady state: evict + admit a LONGER prompt
    # (different block count under paging), then step — 0 retraces
    eng._free(1)
    kdigest.STATS.reset()
    eng.admit(mk(n_slots + 1, plen=29), 1)
    for _ in range(max(1, canary_slices)):
        eng.engine_step()
    _, _, tr = kdigest.STATS.snapshot()
    assert tr == 0, f"slot admission retraced ({tr} digest retraces)"
    return {
        "steps": steps,
        "paged": eng.paged,
        "warmup_wall_s": warm_s,
        "launches_per_step": launches / steps,
        "syncs_per_step": syncs / steps,
        "retraces_per_step": traces / steps,
        "admit_retraces": tr,
    }


def run(*, arch: str = "iterpro-100m", smoke: bool = True,
        n_requests: int = 24, qps: float = 8.0, prompt_len: int = 12,
        gen_tokens: int = 16, n_slots: int = 4, canary_slices: int = 4,
        inject_every: int = 8, seed: int = 0, donate: bool = True,
        mesh: Optional[str] = None, paged=None, block_size: int = 8,
        prefill_chunk: int = 0, long_prompt: int = 0,
        long_every: int = 0) -> Dict:
    """Target QPS should sit BELOW the engine's capacity (smoke on CPU:
    ~4 slots x ~250 tokens/s / 16 tokens ≈ 15-60 req/s) — an overloaded
    open-loop queue measures backlog growth, not fault cost."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    ctx = None
    if mesh:
        from repro.launch.mesh import make_context
        ctx = make_context(mesh)

    max_len = max(prompt_len, long_prompt) + gen_tokens + 1
    mk_engine = lambda **kw: ServingEngine(
        cfg, n_slots=n_slots, max_len=max_len, canary_slices=canary_slices,
        donate=donate, ctx=ctx, seed=seed, paged=paged,
        block_size=block_size,
        prefill_chunk=kw.pop("prefill_chunk", prefill_chunk))
    mk_reqs = lambda rng_seed, n=n_requests, q=qps: _make_requests(
        cfg, n, prompt_len, gen_tokens, q, np.random.default_rng(rng_seed),
        long_prompt=long_prompt, long_every=long_every)

    # preflight: compile EVERYTHING off the clock — step executables
    # (warm), prefill/admit (first admissions — including a long-prompt
    # one, which traces nothing new but pays XLA autotuning), and the
    # fault path's per-block refresh digests (a mini-storm).  All caches
    # are shared at module/plan level, so the timed engines below start
    # fully hot.
    pre = mk_engine()
    pre.warm()
    pre.run(mk_reqs(seed + 1, n=2 * n_slots, q=1e9),
            inject_every=2, inject_rng=random.Random(seed + 1))

    # baseline: same schedule, no storm.  Both engines share the global
    # executable cache (same plan/K/S/signature), so only the first warm
    # pays compilation.
    base = mk_engine()
    base_reqs = mk_reqs(seed)
    base.warm()
    t0 = time.perf_counter()
    base_rep = base.run(base_reqs)
    base_wall = time.perf_counter() - t0

    storm = mk_engine()
    storm_reqs = mk_reqs(seed)
    storm.warm()
    t0 = time.perf_counter()
    storm_rep = storm.run(storm_reqs, inject_every=inject_every,
                          inject_rng=random.Random(seed))
    storm_wall = time.perf_counter() - t0

    injured = storm_rep.injured_rids
    healthy = [r.rid for r in storm_reqs if r.rid not in injured]

    # --- hard assert: zero dropped healthy requests ----------------------
    dropped_healthy = [rid for rid in healthy
                       if storm_rep.per_request.get(rid, {}).get("dropped",
                                                                True)]
    assert not dropped_healthy, (
        f"healthy requests dropped under the storm: {dropped_healthy}")

    # --- hard assert: healthy outputs bit-identical to fault-free run ----
    mismatched = [
        rid for rid in healthy
        if storm_rep.per_request[rid]["tokens"]
        != base_rep.per_request[rid]["tokens"]]
    assert not mismatched, (
        f"slot isolation violated — healthy requests diverged: {mismatched}")
    injured_exact = [
        rid for rid in sorted(injured)
        if rid in storm_rep.per_request
        and not storm_rep.per_request[rid].get("dropped")
        and storm_rep.per_request[rid]["tokens"]
        == base_rep.per_request[rid]["tokens"]]

    # --- SLO numbers -----------------------------------------------------
    def added(rids):
        out = []
        for rid in rids:
            s = storm_rep.per_request.get(rid)
            b = base_rep.per_request.get(rid)
            if s and b and not s.get("dropped"):
                out.append(1e3 * (s["e2e_s"] - b["e2e_s"]))
        return out

    added_healthy = added(healthy)
    added_injured = added(sorted(injured))
    rec = storm_rep.recovery_ms
    ss = steady_state(cfg, n_slots=n_slots, canary_slices=canary_slices,
                      seed=seed, paged=paged, block_size=block_size)

    # --- chunked-prefill fairness: same schedule, fault-free, monolithic
    # vs chunked; the claim is that chunking BOUNDS what a long prompt's
    # prefill adds to short requests' latency.  Measured loosely (wall
    # clock on shared CI hardware) but token equality and completion are
    # exact asserts.
    fairness = None
    if prefill_chunk > 0 and base.paged:
        # monolithic preflight: the chunked preflight above never compiled
        # the per-prompt-length monolithic prefill executables — pay them
        # off the clock so the comparison is prefill POLICY, not compiles
        pre_m = mk_engine(prefill_chunk=0)
        pre_m.warm()
        pre_m.run(mk_reqs(seed + 2, n=2 * n_slots, q=1e9))
        mono = mk_engine(prefill_chunk=0)
        mono_reqs = mk_reqs(seed)
        mono.warm()
        t0 = time.perf_counter()
        mono_rep = mono.run(mono_reqs)
        mono_wall = time.perf_counter() - t0
        assert mono_rep.completed == n_requests and mono_rep.dropped == 0
        assert base_rep.completed == n_requests and base_rep.dropped == 0
        toks = lambda rep: {rid: r["tokens"]
                            for rid, r in rep.per_request.items()}
        assert toks(mono_rep) == toks(base_rep), (
            "chunked prefill changed output tokens vs monolithic")
        short = [r.rid for r in mono_reqs if len(r.prompt) <= prompt_len]
        e2e = lambda rep: [1e3 * rep.per_request[rid]["e2e_s"]
                           for rid in short]
        mono_p99, chunk_p99 = _pct(e2e(mono_rep), 99), _pct(e2e(base_rep),
                                                            99)
        assert chunk_p99 <= mono_p99 * 2.0 + 100.0, (
            f"chunked prefill made short requests WORSE: p99 "
            f"{chunk_p99:.1f} ms vs monolithic {mono_p99:.1f} ms")
        fairness = {
            "prefill_chunk": prefill_chunk,
            "short_requests": len(short),
            "short_p99_ms_monolithic": mono_p99,
            "short_p99_ms_chunked": chunk_p99,
            "short_p50_ms_monolithic": _pct(e2e(mono_rep), 50),
            "short_p50_ms_chunked": _pct(e2e(base_rep), 50),
            "wall_s_monolithic": mono_wall,
            "tokens_bit_identical": True,           # asserted above
        }

    out = {
        "config": {"arch": arch, "smoke": smoke, "n_requests": n_requests,
                   "qps_target": qps, "prompt_len": prompt_len,
                   "gen_tokens": gen_tokens, "n_slots": n_slots,
                   "canary_slices": canary_slices,
                   "inject_every_tokens": inject_every, "seed": seed,
                   "donate": donate, "mesh": mesh,
                   "paged": base.paged, "block_size": block_size,
                   "prefill_chunk": prefill_chunk,
                   "long_prompt": long_prompt, "long_every": long_every},
        "baseline": {"wall_s": base_wall,
                     "tokens_per_s": base_rep.tokens_out / base_wall,
                     "qps_achieved": base_rep.completed / base_wall},
        "storm": {"wall_s": storm_wall,
                  "tokens_per_s": storm_rep.tokens_out / storm_wall,
                  "qps_achieved": storm_rep.completed / storm_wall,
                  "summary": storm_rep.summary()},
        "faults": storm_rep.summary()["faults"],
        "injured_requests": sorted(injured),
        "healthy_requests": len(healthy),
        "dropped_healthy": 0,                       # asserted above
        "dropped_total": storm_rep.dropped,
        "healthy_bit_identical": True,              # asserted above
        "injured_bit_identical": len(injured_exact),
        "added_latency_ms": {
            "healthy": {"p50": _pct(added_healthy, 50),
                        "p99": _pct(added_healthy, 99),
                        "mean": float(np.mean(added_healthy))
                        if added_healthy else 0.0},
            "injured": {"p50": _pct(added_injured, 50),
                        "p99": _pct(added_injured, 99),
                        "mean": float(np.mean(added_injured))
                        if added_injured else 0.0},
        },
        "recovery_ms": {"n": len(rec), "mean": float(np.mean(rec))
                        if rec else 0.0,
                        "p50": _pct(rec, 50), "p99": _pct(rec, 99)},
        "replay_tokens": storm_rep.replay_tokens,
        "retracted_tokens": storm_rep.retracted_tokens,
        "admission_rejected": storm_rep.admission_rejected,
        "steady_state": ss,
        "chunked_prefill": fairness,
    }
    return out


def bench_record(out: Dict) -> Dict:
    """The compact cross-PR trajectory record (BENCH_serving.json)."""
    return {
        "qps_target": out["config"]["qps_target"],
        "qps_achieved": out["storm"]["qps_achieved"],
        "tokens_per_s": out["storm"]["tokens_per_s"],
        "p99_added_latency_ms":
            out["added_latency_ms"]["healthy"]["p99"],
        "p99_added_latency_ms_injured":
            out["added_latency_ms"]["injured"]["p99"],
        "dropped_requests": out["dropped_total"],
        "dropped_healthy": out["dropped_healthy"],
        "faults_injected": out["faults"]["injected"],
        "faults_recovered": out["faults"]["recovered"],
        "mean_recovery_ms": out["recovery_ms"]["mean"],
        "steady_state_launches_per_step":
            out["steady_state"]["launches_per_step"],
        "steady_state_syncs_per_step":
            out["steady_state"]["syncs_per_step"],
        "paged": out["config"]["paged"],
        **({"short_p99_ms_monolithic":
                out["chunked_prefill"]["short_p99_ms_monolithic"],
            "short_p99_ms_chunked":
                out["chunked_prefill"]["short_p99_ms_chunked"]}
           if out.get("chunked_prefill") else {}),
    }


def write_bench(out: Dict, path: str = DEFAULT_OUT) -> str:
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(bench_record(out), f, indent=1)
        f.write("\n")
    return path


def render(out: Dict) -> str:
    c = out["config"]
    al, rc = out["added_latency_ms"], out["recovery_ms"]
    ss = out["steady_state"]
    lines = ["## Serving SLO under a fault storm (live-traffic downtime)",
             ""]
    lines.append(
        f"open-loop Poisson arrivals at {c['qps_target']:g} QPS, "
        f"{c['n_requests']} requests x {c['gen_tokens']} tokens, "
        f"{c['n_slots']} slots, canary K={c['canary_slices']}, one bit "
        f"flip per {c['inject_every_tokens']} accepted tokens")
    lines.append("")
    lines.append("| run | tokens/s | QPS achieved | dropped |")
    lines.append("|---|---|---|---|")
    lines.append(f"| fault-free | {out['baseline']['tokens_per_s']:.0f} "
                 f"| {out['baseline']['qps_achieved']:.1f} | 0 |")
    lines.append(f"| fault storm | {out['storm']['tokens_per_s']:.0f} "
                 f"| {out['storm']['qps_achieved']:.1f} "
                 f"| {out['dropped_total']} |")
    lines.append("")
    f = out["faults"]
    lines.append(
        f"- storm: {f['injected']} injected, {f['detected']} detected, "
        f"{f['recovered']} recovered; {len(out['injured_requests'])} "
        f"injured requests paid prefix replay "
        f"({out['replay_tokens']} replay tokens, "
        f"{out['retracted_tokens']} retracted)")
    lines.append(
        f"- healthy requests ({out['healthy_requests']}): 0 dropped "
        f"(asserted), bit-identical to fault-free run (asserted); added "
        f"latency p50 {al['healthy']['p50']:.1f} ms / "
        f"p99 {al['healthy']['p99']:.1f} ms")
    lines.append(
        f"- injured requests: {out['injured_bit_identical']}/"
        f"{len(out['injured_requests'])} still bit-identical (replay "
        f"determinism); added latency p50 {al['injured']['p50']:.1f} ms / "
        f"p99 {al['injured']['p99']:.1f} ms")
    lines.append(
        f"- recovery wall per fault: mean {rc['mean']:.1f} ms, "
        f"p50 {rc['p50']:.1f} ms, p99 {rc['p99']:.1f} ms over {rc['n']} "
        f"evictions (detection -> victim re-admitted)")
    lines.append(
        f"- steady-state hot path (asserted, "
        f"{'paged' if ss.get('paged') else 'dense'} KV): "
        f"{ss['launches_per_step']:g} logical launch + "
        f"{ss['syncs_per_step']:g} scalar fault sync + "
        f"{ss['retraces_per_step']:g} retraces per engine step; slot "
        f"turnover (incl. block churn) retraced "
        f"{ss['admit_retraces']} times")
    fz = out.get("chunked_prefill")
    if fz:
        lines.append(
            f"- chunked prefill (chunk={fz['prefill_chunk']}, long-prompt "
            f"mix, fault-free, tokens bit-identical asserted): short-"
            f"request e2e p99 {fz['short_p99_ms_chunked']:.1f} ms chunked "
            f"vs {fz['short_p99_ms_monolithic']:.1f} ms monolithic "
            f"({fz['short_requests']} short requests)")
    if out.get("admission_rejected"):
        lines.append(
            f"- admission rejected (over-budget, typed): "
            f"{out['admission_rejected']}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="iterpro-100m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--qps", type=float, default=8.0)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--canary-slices", type=int, default=4)
    ap.add_argument("--inject", type=int, default=8,
                    help="one bit flip per N accepted tokens")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--block-size", type=int, default=8,
                    help="paged-KV block size (token positions)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="chunked prefill size (0: monolithic); >0 also "
                         "runs the chunked-vs-monolithic fairness section")
    ap.add_argument("--dense", action="store_true",
                    help="force the dense per-slot KV cache")
    ap.add_argument("--long-prompt", type=int, default=0,
                    help="heterogeneous mix: every Nth request (see "
                         "--long-every) carries a prompt this long")
    ap.add_argument("--long-every", type=int, default=0)
    ap.add_argument("--out", default=DEFAULT_OUT,
                    help="path for BENCH_serving.json ('' to skip)")
    args = ap.parse_args()

    out = run(arch=args.arch, smoke=args.smoke, n_requests=args.requests,
              qps=args.qps, prompt_len=args.prompt_len,
              gen_tokens=args.gen, n_slots=args.slots,
              canary_slices=args.canary_slices, inject_every=args.inject,
              seed=args.seed, mesh=args.mesh,
              paged=False if args.dense else None,
              block_size=args.block_size, prefill_chunk=args.prefill_chunk,
              long_prompt=args.long_prompt, long_every=args.long_every)
    print(render(out))
    if args.out:
        path = write_bench(out, args.out)
        print(f"\nwrote {path}")


if __name__ == "__main__":
    main()
