"""Benchmark runner — one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--trials N] [--quick] [--skip-roofline]

Writes benchmarks/results.json and prints the rendered tables.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np


def _jsonable(x):
    if isinstance(x, dict):
        return {k: _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, (np.floating, np.integer)):
        return float(x)
    return x


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trials", type=int, default=60)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "results.json"))
    args = ap.parse_args()
    n = 16 if args.quick else args.trials

    from benchmarks import injection_outcomes, overhead, recoverable_ivs, recovery, roofline
    from benchmarks._campaign import Campaign

    t0 = time.time()
    print("=" * 72)
    print("IterPro-JAX benchmark suite — one section per paper table/figure")
    print(f"(fault-injection trials per campaign: {n}; paper used 5-10k "
          f"per workload on a 48-core x86 box)")
    print("=" * 72, flush=True)

    print("\n[1/7] building campaign (fault-free reference trajectory)...",
          flush=True)
    campaign = Campaign()

    results = {}

    print("[2/7] injection outcomes (Tables 3-5)...", flush=True)
    out1 = injection_outcomes.run(campaign, n_trials=n)
    results["injection_outcomes"] = {k: v for k, v in out1.items()
                                     if not k.startswith("_")}
    print()
    print(injection_outcomes.render(out1))

    print("\n[3/7] recovery rate/time + CARE ablation (Figs 7, 8, 10)...",
          flush=True)
    out2 = recovery.run(campaign, n_trials=n)
    results["recovery"] = out2
    print()
    print(recovery.render(out2))

    print("\n[4/7] no-fault overhead (Fig 9)...", flush=True)
    out3 = overhead.run(campaign, steps=10 if args.quick else 30)
    results["overhead"] = out3
    print()
    print(overhead.render(out3))

    print("\n[5/7] recoverable IVs (Table 6)...", flush=True)
    out4 = recoverable_ivs.run()
    results["recoverable_ivs"] = out4
    print()
    print(recoverable_ivs.render(out4))

    print("\n[6/7] serving SLO under a fault storm...", flush=True)
    from benchmarks import serving_slo
    out_serve = serving_slo.run(n_requests=8 if args.quick else 24,
                                inject_every=6 if args.quick else 8)
    results["serving"] = out_serve
    print()
    print(serving_slo.render(out_serve))
    print(f"wrote {serving_slo.write_bench(out_serve)}")

    print("\n[7/7] downtime per fault (title claim)...", flush=True)
    from benchmarks import downtime
    out6 = downtime.run(campaign, n_trials=12 if args.quick else 24,
                        serving=out_serve)
    results["downtime"] = out6
    print()
    print(downtime.render(out6))

    if not args.skip_roofline:
        try:
            out5 = roofline.run()
            print()
            print(roofline.render(out5, mesh="single"))
            print()
            print(roofline.render(out5, mesh="multi"))
            results["roofline_cells"] = len(out5["cells"])
        except FileNotFoundError:
            print("\n(no dryrun_results.json — run the dry-run sweep first)")

    with open(args.out, "w") as f:
        json.dump(_jsonable(results), f, indent=1)
    print(f"\nwrote {args.out}  ({time.time() - t0:.0f}s total)")


if __name__ == "__main__":
    main()
